"""Paper Fig. 4: SpMM throughput (GFLOPS), FP64 and FP32, LOOPS vs CPU
baselines across the Table-2-like suite.

Baselines (implemented, per assignment scope):
  * taco-like   — row-wise CSR schedule in pure XLA (segment-sum), the
                  schedule TACO emits for CSR SpMM;
  * armadillo-like — dense GEMM on the densified operand (Armadillo stores
                  sparse, but its SpMM lowers to generic kernels; the dense
                  GEMM is the upper-bound-friendly stand-in).

Container caveat (recorded in EXPERIMENTS.md): wall-clock numbers are
CPU-XLA proxies — this machine has ONE homogeneous engine, so the paper's
heterogeneous-engine speedup mechanism cannot appear in wall-clock; what IS
reproducible here is the *adaptive scheduling* claim: the calibrated perf
model (Eq. 2) discovers the machine's best split per matrix (on CPU that is
usually CSR-heavy; on the TPU target the roofline terms in §Roofline carry
the perf claim).  The Pallas kernels are TPU-targeted and validated in
interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (csr_to_dense, loops_from_csr, loops_spmm,
                        plan_and_convert, spmm_csr_baseline,
                        spmm_dense_baseline, suite)
from repro.core.partition import choose_r_boundary
from repro.core.perf_model import calibrate

from ._util import csv_row, gflops, time_fn

N = 32  # paper fixes N=32
MATRICES = ["m6", "m8", "m9", "m10", "m12", "m13", "m14", "m16", "m17", "m19"]


def calibrated_plan(csr, b, total: int = 4):
    """Paper §3.5: fit Eq. 2 from warm-up runs of candidate splits, then
    argmax (Eq. 3) -> boundary (Eq. 1)."""
    def measure(x, y):
        r = choose_r_boundary(csr.nrows, 1.0, 4.0, max(x, 0), max(y, 0),
                              br=8)
        fmt = loops_from_csr(csr, r, 8)
        f = jax.jit(lambda bb: loops_spmm(fmt, bb, backend="jnp"))
        return 1.0 / time_fn(f, b, repeats=2, warmup=1)

    model = calibrate(measure, total=total)
    return plan_and_convert(csr, total_workers=total, model=model)


def run(dtype=np.float32, scale_rows: int = 1024, out=print):
    name_dt = {np.float32: "fp32", np.float64: "fp64"}[dtype]
    if dtype == np.float64:
        jax.config.update("jax_enable_x64", True)
    try:
        rng = np.random.default_rng(0)
        rows = []
        for mid in MATRICES:
            csr = suite.table2_like(mid, scale_rows=scale_rows, seed=3,
                                    dtype=dtype)
            nnz = csr.nnz
            b = jnp.asarray(rng.standard_normal((csr.shape[1], N)), dtype)
            fmt, plan = calibrated_plan(csr, b)
            dense = jnp.asarray(csr_to_dense(csr))

            f_loops = jax.jit(lambda bb: loops_spmm(fmt, bb, backend="jnp"))
            f_taco = jax.jit(lambda bb: spmm_csr_baseline(csr, bb))
            f_arma = jax.jit(lambda bb: spmm_dense_baseline(dense, bb))

            t_loops = time_fn(f_loops, b)
            t_taco = time_fn(f_taco, b)
            t_arma = time_fn(f_arma, b)
            g = gflops(nnz, N, t_loops)
            out(csv_row(f"fig4_{name_dt}_{mid}_{suite.TABLE2_STATS[mid].name}",
                        t_loops * 1e6,
                        f"GFLOPS={g:.2f};vs_taco={t_taco / t_loops:.2f}x;"
                        f"vs_dense={t_arma / t_loops:.2f}x"))
            rows.append((t_taco / t_loops, t_arma / t_loops))
        sp = np.array(rows)
        out(csv_row(f"fig4_{name_dt}_geomean", 0.0,
                    f"speedup_vs_taco={np.exp(np.log(sp[:, 0]).mean()):.2f}x;"
                    f"speedup_vs_dense={np.exp(np.log(sp[:, 1]).mean()):.2f}x"))
    finally:
        if dtype == np.float64:
            jax.config.update("jax_enable_x64", False)


def main(out=print):
    run(np.float32, out=out)
    run(np.float64, out=out)


if __name__ == "__main__":
    main()
