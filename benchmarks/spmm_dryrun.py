import os
if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    # Standalone CLI only: must run before the jax import below.  When
    # imported by benchmarks/run.py for suite registration this must NOT
    # fire — jax is usually initialised already and forcing 512 host
    # devices would reshape every other suite.  run.py instead calls
    # bench_main(), which skip-records unless the mesh is actually there.
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Paper-technique production cell: distributed LOOPS SpMM on the full mesh.

The two-level schedule (device groups = the paper's thread groups, kernel
grids = its row parallelism) lowered for the single-pod 16x16 mesh (256
SpMM workers over the flattened ("data","model") axis) at SuiteSparse scale:
an in-2004-like web matrix (1.4M rows, ~17M nnz, power-law skew) with N=32.

Writes a dryrun-style JSON (tag 'spmm') so §Roofline/§Perf treat it like any
other cell.  ``--set g_frac=<f>`` and ``--set boundary_frac=<f>`` expose the
scheduler knobs for hillclimbing.

Registered in benchmarks/run.py as suite ``spmm_dryrun`` via
:func:`bench_main`: it needs the forced 256-worker host platform, so under
a normally-initialised runtime it emits a schema'd skip record instead of
numbers (run it standalone — ``python -m benchmarks.spmm_dryrun`` — to get
the real cell).
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1_400_000)
    ap.add_argument("--mean-nnz", type=float, default=12.23)  # in-2004
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--g-frac", type=float, default=None,
                    help="fraction of devices in the CSR/vector group "
                         "(default: perf-model heuristic)")
    ap.add_argument("--boundary-frac", type=float, default=None,
                    help="override r_boundary/nrows")
    ap.add_argument("--tag", default="spmm")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--no-assemble", action="store_true",
                    help="§Perf: keep C row-sharded (skip the reassembly "
                         "collectives)")
    ap.add_argument("--sorted", action="store_true",
                    help="§Perf: nnz-descending row sort before the split "
                         "(hubs -> CSR part; kills BCSR block-row padding)")
    args = ap.parse_args()

    from repro.core import (csr_from_coo, loops_from_csr, plan_and_convert,
                            shard_loops)
    from repro.core.formats import loops_from_csr_sorted
    from repro.core.distributed import distributed_spmm
    from repro.launch.mesh import make_production_mesh
    from repro.perf.hlo_analysis import analyze_hlo

    t0 = time.time()
    rng = np.random.default_rng(0)
    n = args.rows
    raw = rng.pareto(1.1, n) + 1.0
    counts = np.minimum((raw / raw.mean() * args.mean_nnz).astype(np.int64),
                        n)
    rows = np.repeat(np.arange(n, dtype=np.int64), counts)
    cols = rng.integers(0, n, rows.shape[0])
    vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
    csr = csr_from_coo(rows, cols, vals, (n, n))
    print(f"matrix built: {csr.shape} nnz={csr.nnz} "
          f"({time.time() - t0:.1f}s)", flush=True)

    mesh = make_production_mesh(multi_pod=False)
    D = 256
    from repro.core.partition import choose_r_boundary
    t_mxu = max(int(round(D * 4.0 / 5.0)), 1)  # tp_mxu / (tp_vpu + tp_mxu)
    t_vpu = max(D - t_mxu, 1)
    if args.boundary_frac is not None:
        r_b = int(args.boundary_frac * n) // 8 * 8
    else:
        r_b = choose_r_boundary(n, 1.0, 4.0, t_vpu, t_mxu, br=8)
    g_vpu = (max(int(args.g_frac * D), 1) if args.g_frac is not None
             else t_vpu)
    if args.sorted:
        fmt, order = loops_from_csr_sorted(csr, r_b, 8)
    else:
        fmt = loops_from_csr(csr, r_b, 8)
    print(f"format: r_boundary={fmt.r_boundary} g_vpu={g_vpu} "
          f"({time.time() - t0:.1f}s)", flush=True)

    sharded = shard_loops(fmt, D, g_vpu)
    b_aval = jax.ShapeDtypeStruct((n, args.n), jnp.float32)
    avals = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        (sharded.row_ids, sharded.col_idx, sharded.vals, sharded.tile_rows,
         sharded.tile_cols, sharded.tile_vals))

    import dataclasses
    def run(row_ids, col_idx, vals_, tile_rows, tile_cols, tile_vals, b):
        sh = dataclasses.replace(
            sharded, row_ids=row_ids, col_idx=col_idx, vals=vals_,
            tile_rows=tile_rows, tile_cols=tile_cols, tile_vals=tile_vals)
        return distributed_spmm(sh, b, mesh, axis=("data", "model"),
                                assemble=not args.no_assemble)

    t1 = time.time()
    lowered = jax.jit(run).lower(*avals, b_aval)
    compiled = lowered.compile()
    t2 = time.time()
    hlo = compiled.as_text()
    st = analyze_hlo(hlo)
    rec = {
        "arch": "loops-spmm-in2004", "shape": f"spmm_n{args.n}",
        "mesh": "single", "mesh_shape": dict(mesh.shape), "status": "ok",
        "tag": args.tag,
        "overrides": {"g_frac": args.g_frac,
                      "boundary_frac": args.boundary_frac,
                      "r_boundary": int(fmt.r_boundary),
                      "g_vpu": int(g_vpu), "nnz": int(csr.nnz),
                      "rows_pad": int(sharded.rows_pad)},
        "compile_s": round(t2 - t1, 2),
        "hlo": {
            "flops_per_device": st.flops,
            "hbm_bytes_per_device": st.hbm_bytes,
            "collective_bytes_per_device": st.collective_bytes,
            "collective_by_kind": st.collective_by_kind,
            "unknown_trip_loops": st.unknown_trip_loops,
            "text_len": len(hlo),
        },
    }
    try:
        rec["memory_analysis"] = {
            "argument_size_in_bytes":
                int(compiled.memory_analysis().argument_size_in_bytes),
            "temp_size_in_bytes":
                int(compiled.memory_analysis().temp_size_in_bytes),
        }
    except Exception:
        pass
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out = os.path.join(RESULTS_DIR,
                       f"loops-spmm__{args.tag}__single.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    if args.keep_hlo:
        with open(out.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo)
    flops = st.flops
    useful = 2.0 * csr.nnz * args.n / 256
    print(f"[ok] compile={t2 - t1:.1f}s flops/dev={flops:.3e} "
          f"useful/dev={useful:.3e} ratio={useful / max(flops, 1):.3f}")
    print(f"     hbm/dev={st.hbm_bytes / 1e9:.3f} GB  "
          f"coll/dev={st.collective_bytes / 1e6:.3f} MB -> {out}")


def bench_main(out=print, record=None, smoke: bool = False):
    """Registry entry point (suite ``spmm_dryrun`` in benchmarks/run.py).

    The cell hard-requires the 256-worker mesh
    (:func:`repro.launch.mesh.make_production_mesh`); a normally-initialised
    runtime can't grow devices after the fact, so anything smaller emits a
    schema'd skip record — the bench.json row still exists, CI still
    validates it, and the reason points at the standalone CLI.
    """
    import jax

    if jax.device_count() < 256:
        reason = (f"needs 256 devices for the production mesh, have "
                  f"{jax.device_count()}; run standalone: "
                  "python -m benchmarks.spmm_dryrun")
        out(f"spmm_dryrun_SKIPPED,0.0,{reason}")
        if record is not None:
            record({"suite": "spmm_dryrun", "skipped": True,
                    "reason": reason})
        return
    import sys

    argv, sys.argv = sys.argv, [sys.argv[0]]
    if smoke:
        sys.argv += ["--rows", "100000", "--tag", "spmm-smoke"]
    try:
        main()
    finally:
        sys.argv = argv


if __name__ == "__main__":
    main()
