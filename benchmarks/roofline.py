"""§Roofline: per (arch x shape) three-term roofline from the compiled
dry-run artifacts (single-pod mesh).

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
  memory term     = HLO_bytes_per_device / HBM_bw_per_chip
  collective term = collective_bytes_per_device / link_bw

HLO_* come from the trip-count-corrected HLO analyzer (repro.perf) over the
post-SPMD per-device module, so "/ chips" is already applied.  MODEL_FLOPS
uses 6*N*D for training cells and 2*N*D for inference cells (N = active
params for MoE).  Emits benchmarks/results/roofline.md + CSV rows.
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.configs import SHAPES, get_config

from ._util import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, csv_row

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "results", "roofline.md")


def active_params(cfg) -> float:
    """Analytic active-parameter count (MoE: top_k of the routed experts)."""
    d, L = cfg.d_model, cfg.num_layers
    hd = cfg.resolved_head_dim
    attn = d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    if cfg.family == "moe":
        ffn = 3 * d * cfg.moe_d_ff * cfg.top_k
        if cfg.num_shared_experts:
            ffn += 3 * d * cfg.d_ff + d  # shared expert + gate
        ffn += d * cfg.num_experts  # router
    elif cfg.family == "ssm":
        attn = 5 * d * d + 2 * d * 32 * 5  # rwkv time-mix proj + lora approx
        ffn = d * cfg.d_ff * 2 + d * d
    else:
        ffn = 3 * d * cfg.d_ff if cfg.act == "swiglu" else 2 * d * cfg.d_ff
    if cfg.family == "hybrid":
        attn += 2 * d * d + 2 * d * cfg.ssm_state + d * d  # mamba head
    emb = cfg.vocab_padded() * d * (1 if cfg.tie_embeddings else 2)
    total = L * (attn + ffn) + emb
    if cfg.family == "audio":
        total += (cfg.encoder_layers or L) * (attn + 2 * d * cfg.d_ff)
    return float(total)


def total_params(cfg) -> float:
    if cfg.family != "moe":
        return active_params(cfg)
    d, L = cfg.d_model, cfg.num_layers
    hd = cfg.resolved_head_dim
    attn = d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    ffn = 3 * d * cfg.moe_d_ff * cfg.num_experts
    if cfg.num_shared_experts:
        ffn += 3 * d * cfg.d_ff + d
    emb = cfg.vocab_padded() * d * 2
    return float(L * (attn + ffn) + emb)


def model_flops(cfg, shape) -> float:
    n = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def advice(dom: str, shape_kind: str, cfg) -> str:
    if dom == "compute":
        return ("near roofline already; next wins are kernel-level (fused "
                "attention kernel, higher MXU occupancy)")
    if dom == "memory":
        if shape_kind == "decode":
            return ("decode is weight/cache-bandwidth bound: quantise KV "
                    "cache + weights (bf16->int8) or batch more requests "
                    "per chip")
        return ("reduce HBM traffic: less remat recompute, fuse layout "
                "copies, keep activations bf16")
    return ("collective-bound: overlap reduce-scatter with microbatch "
            "compute, int8 gradient compression, or reshard to cut "
            "resharding copies")


def load_cells(mesh="single", tag=""):
    suffix = f"__{mesh}__{tag}.json" if tag else f"__{mesh}.json"
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*{suffix}"))):
        if not tag and "__opt" in path:
            continue
        rec = json.load(open(path))
        if rec.get("status") == "ok":
            cells.append(rec)
    return cells


def main(out=print, tag=None):
    # prefer the optimized-defaults run when present; fall back to baseline
    if tag is None:
        tag = "opt" if glob.glob(os.path.join(RESULTS, "*__opt.json")) else ""
    cells = load_cells("single", tag)
    if not cells:
        cells = load_cells("single", "")
    # paper-technique cell: pick the most-optimized variant present
    if not any(c["arch"].startswith("loops-spmm") for c in cells):
        for t in ("spmm_opt", "spmm_sorted", "spmm_noasm", "spmm"):
            p = os.path.join(RESULTS, f"loops-spmm__{t}__single.json")
            if os.path.exists(p):
                rec = json.load(open(p))
                if rec.get("status") == "ok":
                    cells.append(rec)
                break
    lines = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
             "dominant | roofline frac | MODEL/HLO flops | note |",
             "|---|---|---|---|---|---|---|---|---|"]
    for rec in cells:
        chips = int(np.prod(list(rec["mesh_shape"].values())))
        h = rec["hlo"]
        if rec["arch"].startswith("loops-spmm"):
            # the paper-technique cell: useful flops = 2 * nnz * N
            nnz = rec.get("overrides", {}).get("nnz", 0)
            ncols = int(rec["shape"].split("_n")[-1])
            mf = 2.0 * nnz * ncols
            t_comp = h["flops_per_device"] / PEAK_FLOPS_BF16
            t_mem = h["hbm_bytes_per_device"] / HBM_BW
            t_coll = h["collective_bytes_per_device"] / ICI_BW
            terms = {"compute": t_comp, "memory": t_mem,
                     "collective": t_coll}
            dom = max(terms, key=terms.get)
            t_step = max(terms.values())
            # the XLA path has no MXU dots (rank-1 chains are elementwise
            # here); useful-flops time is the honest compute term
            t_useful = mf / chips / PEAK_FLOPS_BF16
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {t_useful:.3e} | "
                f"{t_mem:.3e} | {t_coll:.3e} | {dom} | "
                f"{t_useful / t_step if t_step else 0:.3f} | n/a | "
                f"paper-technique cell (two-level device-group schedule; "
                f"Pallas kernel runs ~30x less HBM traffic — §Perf) |")
            out(csv_row(f"roofline_{rec['arch']}_{rec['shape']}",
                        t_step * 1e6, f"dom={dom};useful_t={t_useful:.2e}"))
            continue
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        t_comp = h["flops_per_device"] / PEAK_FLOPS_BF16
        t_mem = h["hbm_bytes_per_device"] / HBM_BW
        t_coll = h["collective_bytes_per_device"] / ICI_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dom = max(terms, key=terms.get)
        t_step = max(terms.values())
        frac = t_comp / t_step if t_step > 0 else 0.0
        mf = model_flops(cfg, shape)
        ratio = mf / max(h["flops_per_device"] * chips, 1.0)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {t_comp:.3e} | {t_mem:.3e} "
            f"| {t_coll:.3e} | {dom} | {frac:.3f} | {ratio:.3f} | "
            f"{advice(dom, shape.kind, cfg)} |")
        out(csv_row(f"roofline_{rec['arch']}_{rec['shape']}", t_step * 1e6,
                    f"dom={dom};frac={frac:.3f};model_hlo_ratio={ratio:.3f}"))
    with open(OUT_MD, "w") as f:
        f.write("# Roofline (single-pod 16x16, v5e constants: 197 TF bf16, "
                "819 GB/s HBM, 50 GB/s ICI)\n\n")
        f.write("\n".join(lines) + "\n")
    out(csv_row("roofline_table_written", 0.0, OUT_MD))


if __name__ == "__main__":
    main()
