"""Batched multi-RHS SpMM: per-element loop vs vmap-unrolled vs native.

The paper keeps the matrix engine saturated by feeding wide dense panels;
the batched execution engine (``kernels/engine.py``) extends that to whole
batches of right-hand sides — one engine call with a leading batch grid
dimension, A's static panel layout loaded once per grid step and applied to
every batch slice.  This suite measures the three ways a batched workload
(GNN minibatches, sparse-FFN activations, concurrent serving requests) can
execute the same math:

  * **loop**       — the pre-engine strategy: a Python loop over batch
                     elements, one jitted ``loops_spmm`` dispatch each
                     (``batch ×`` grid steps AND ``batch ×`` dispatches);
  * **vmap**       — trace-time unrolled stack of per-element calls under
                     one jit, mimicking what ``jax.vmap`` lowered to before
                     the custom batching rule (``batch ×`` grid steps, one
                     dispatch);
  * **native**     — ONE batched engine call on the ``(batch, K, N)``
                     operand (``ceil(batch / bz) ×`` the single-element
                     grid steps — equal to them for ``batch ≤ 8``).

Both forward and forward+backward (``grad`` w.r.t. the operand) are timed,
and the grid-step cost proxy (``loops_batched_grid_steps``) is recorded —
the hardware-independent column the acceptance tracking pins: native
batched must beat the per-element loop on grid steps from batch ≥ 4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import csr_from_dense, loops_spmm, plan_and_convert
from repro.core.spmm import loops_batched_grid_steps, loops_grid_steps

from ._util import bench_rng, csv_row, time_fn

N = 32                       # dense columns per RHS (paper fixes N=32)
BATCHES = [1, 4, 8]
SMOKE_BATCHES = [4]
BACKEND = "interpret"        # the real (Pallas) kernel path off-TPU


def _strategies(fmt, batch):
    """(name -> jitted fwd fn of the (batch, K, N) operand); 'loop' is a
    Python loop of per-element dispatches and is returned separately."""
    def native(b3):
        return loops_spmm(fmt, b3, backend=BACKEND)

    def unrolled(b3):
        return jnp.stack([loops_spmm(fmt, b3[i], backend=BACKEND)
                          for i in range(batch)])

    return {"native": jax.jit(native), "vmap": jax.jit(unrolled)}


def main(out=print, record=None, smoke: bool = False):
    scale = 96 if smoke else 256
    density = 0.08
    repeats, warmup = (2, 1) if smoke else (5, 2)
    rng = bench_rng()
    a = ((rng.random((scale, scale // 2)) < density)
         * rng.standard_normal((scale, scale // 2))).astype(np.float32)
    csr = csr_from_dense(a)
    fmt, plan = plan_and_convert(csr, total_workers=8)
    # Piped variant: same plan run macro-fused under the depth-2 pipeline.
    fmt_piped, plan_piped = plan_and_convert(csr, total_workers=8,
                                             pipeline_depth=2, macro_m=4)
    k = csr.shape[1]

    f_elem = jax.jit(lambda b2: loops_spmm(fmt, b2, backend=BACKEND))
    g_elem = jax.jit(jax.grad(lambda b2: jnp.sum(
        loops_spmm(fmt, b2, backend=BACKEND))))

    for batch in (SMOKE_BATCHES if smoke else BATCHES):
        b3 = jnp.asarray(rng.standard_normal((batch, k, N)).astype(np.float32))
        steps_one = loops_grid_steps(fmt, N)
        steps = {"loop": batch * steps_one, "vmap": batch * steps_one,
                 "native": loops_batched_grid_steps(fmt, batch, N)}
        steps_piped = loops_batched_grid_steps(fmt_piped, batch, N)
        fns = _strategies(fmt, batch)
        f_piped = jax.jit(lambda b3_: loops_spmm(fmt_piped, b3_,
                                                 backend=BACKEND))

        # Parity: native batched == vmap-unrolled (the acceptance contract),
        # and the macro-fused depth-2 pipeline must agree with both.
        ref = np.asarray(fns["vmap"](b3))
        np.testing.assert_allclose(np.asarray(fns["native"](b3)), ref,
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(f_piped(b3)), ref,
                                   rtol=1e-4, atol=1e-4)

        times = {}
        # Per-element Python loop: batch separate dispatches.
        def loop_fwd(b3_):
            return [f_elem(b3_[i]) for i in range(batch)]
        times[("loop", "fwd")] = time_fn(loop_fwd, b3, repeats=repeats,
                                         warmup=warmup)

        def loop_fwdbwd(b3_):
            return [g_elem(b3_[i]) for i in range(batch)]
        times[("loop", "fwdbwd")] = time_fn(loop_fwdbwd, b3, repeats=repeats,
                                            warmup=warmup)
        for name, fn in fns.items():
            times[(name, "fwd")] = time_fn(fn, b3, repeats=repeats,
                                           warmup=warmup)
            gfn = jax.jit(jax.grad(lambda bb, f=fn: jnp.sum(f(bb))))
            times[(name, "fwdbwd")] = time_fn(gfn, b3, repeats=repeats,
                                              warmup=warmup)
        times[("piped", "fwd")] = time_fn(f_piped, b3, repeats=repeats,
                                          warmup=warmup)

        for name in ("loop", "vmap", "native"):
            out(csv_row(
                f"batched_b{batch}_{name}", times[(name, "fwd")] * 1e6,
                f"grid_steps={steps[name]};"
                f"fwdbwd_us={times[(name, 'fwdbwd')] * 1e6:.1f};"
                f"steps_vs_loop={steps['loop'] / max(steps[name], 1):.2f}x"))
        out(csv_row(
            f"batched_b{batch}_piped", times[("piped", "fwd")] * 1e6,
            f"grid_steps={steps_piped};pipeline_depth=2;macro_m=4;"
            f"steps_vs_loop={steps['loop'] / max(steps_piped, 1):.2f}x"))
        if batch >= 4:
            assert steps["native"] < steps["loop"], \
                (f"native batched must beat the per-element loop on grid "
                 f"steps at batch={batch}: {steps['native']} vs "
                 f"{steps['loop']}")
        if record is not None:
            record({
                "suite": "batched", "batch": batch, "n_cols": N,
                "panel_g": plan.panel_g,
                "pipeline_depth": getattr(plan_piped, "pipeline_depth", 1),
                "macro_m": getattr(plan_piped, "macro_m", 1),
                "grid_steps_loop": steps["loop"],
                "grid_steps_native": steps["native"],
                "grid_steps_piped": steps_piped,
                "fwd_us_piped": times[("piped", "fwd")] * 1e6,
                "step_reduction_vs_loop":
                    steps["loop"] / max(steps["native"], 1),
                "fwd_us_loop": times[("loop", "fwd")] * 1e6,
                "fwd_us_vmap": times[("vmap", "fwd")] * 1e6,
                "fwd_us_native": times[("native", "fwd")] * 1e6,
                "fwdbwd_us_loop": times[("loop", "fwdbwd")] * 1e6,
                "fwdbwd_us_vmap": times[("vmap", "fwdbwd")] * 1e6,
                "fwdbwd_us_native": times[("native", "fwdbwd")] * 1e6,
            })


if __name__ == "__main__":
    main()
